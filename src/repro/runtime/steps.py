"""Train / serve step builders: the jit boundary of the framework.

``make_train_step``: cross-entropy LM loss -> grads -> clip -> optimizer.
Distribution is GSPMD: params/opt-state shardings come from the rules
(FSDP x TP), the batch is dp-sharded, and XLA's latency-hiding scheduler
overlaps the gradient reduce with the backward pass.

Cross-pod **gradient compression** (``grad_compression="int8"``): the only
cross-pod traffic in the hierarchical scheme is the gradient all-reduce.
With compression on, the step runs under ``shard_map`` manual over the
"pod" axis only (data/model stay auto/GSPMD): per-pod gradients are
stochastically rounded to int8 (unbiased — core.quant), all-gathered over
"pod" as int8 (half the bytes of a bf16 all-reduce), and dequant-summed
locally.  This is the paper's 8-bit insight applied to the interconnect,
and it shows up directly in the dry-run's collective-bytes term.

``make_serve_step``: prefill (full forward) and decode (one token against
the KV cache) with static shapes — the TPU's deterministic-execution
argument applied to the serving runtime (predictable p99, Table 4).

``make_decode_loop``: the fused serving hot loop — ``lax.scan`` over N
decode steps inside ONE jit boundary (one dispatch per *sequence* instead
of one per token), with the KV cache donated so XLA updates it in place,
and ``bucket_batch`` rounding request batches to a fixed ladder of shapes
so the jit cache stays small and recompiles never land on the hot path.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core.qlinear import FP, QuantMode
from repro.core.quant import compute_scale, int_bounds
from repro.models import registry as R
from repro.optim import Optimizer, clip_by_global_norm
from repro.runtime import sharding as S


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean CE in fp32 + z-loss (logit-norm stabilizer, production recipe)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - ll)
    return ce + z_loss * jnp.mean(jnp.square(lse))


def make_loss_fn(cfg: ArchConfig, *, mode: QuantMode = FP,
                 remat: bool = True) -> Callable:
    def loss_fn(params, batch):
        logits = R.apply_forward(params, cfg, batch, mode=mode, remat=remat)
        return cross_entropy(logits, batch["labels"])
    return loss_fn


# ---------------------------------------------------------------------------
# int8 cross-pod gradient exchange
# ---------------------------------------------------------------------------

def supports_int8_grad_exchange() -> bool:
    """True when the installed XLA can partition the int8 cross-pod
    gradient exchange.  The partitioner bundled with JAX 0.4.x aborts
    (``Check failed: sharding.IsManualSubgroup()``) when partitioning a
    scan *backward* pass under partial-manual shard_map — and every model
    here scans over layers — so the exchange needs the newer partitioner
    that ships alongside ``jax.shard_map``."""
    return hasattr(jax, "shard_map")


def _int8_allreduce_pod(g: jax.Array, key: jax.Array) -> jax.Array:
    """Unbiased int8 all-reduce over the manual "pod" axis.

    quantize (stochastic) -> all_gather int8 (+ scalar scales) -> local
    dequant-sum.  Wire bytes: 1B/elem vs 2-4B for a raw all-reduce.
    Only reachable on JAX versions whose partitioner handles collectives
    under partial-manual shard_map (see supports_int8_grad_exchange).
    """
    scale = compute_scale(g, bits=8)
    qmin, qmax = int_bounds(8)
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.floor(g.astype(jnp.float32) / scale + 0.5 + noise),
                 qmin, qmax).astype(jnp.int8)
    qs = jax.lax.all_gather(q, "pod")                  # (npod, ...)
    ss = jax.lax.all_gather(scale, "pod")              # (npod, 1...)
    total = jnp.sum(qs.astype(jnp.float32)
                    * ss.reshape((qs.shape[0],) + (1,) * g.ndim), axis=0)
    return (total / qs.shape[0]).astype(g.dtype)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, *,
                    mode: QuantMode = FP, remat: bool = True,
                    max_grad_norm: float = 1.0,
                    grad_compression: Optional[str] = None,
                    mesh=None) -> Callable:
    """Returns train_step(params, opt_state, batch, step_rng) ->
    (params, opt_state, metrics)."""
    loss_fn = make_loss_fn(cfg, mode=mode, remat=remat)

    use_int8 = (grad_compression == "int8" and mesh is not None
                and "pod" in mesh.axis_names)
    if use_int8 and not supports_int8_grad_exchange():
        import warnings
        warnings.warn(
            "int8 grad exchange needs a partitioner that handles scan "
            "backward under partial-manual shard_map (JAX with "
            "jax.shard_map); falling back to uncompressed gradients",
            RuntimeWarning, stacklevel=2)
        use_int8 = False

    def _core(params, opt_state, batch, rng):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if use_int8:
            keys = jax.random.split(rng, len(jax.tree.leaves(grads)))
            keys_tree = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(grads), list(keys))
            grads = jax.tree_util.tree_map(
                _int8_allreduce_pod, grads, keys_tree)
            loss = jax.lax.pmean(loss, "pod")
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_state = optimizer.update(params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return new_params, new_state, metrics

    if use_int8:
        from jax.sharding import PartitionSpec as P
        # partial-manual shard_map: only "pod" is manual; data/model stay
        # under GSPMD auto-sharding inside.
        pspec = P()            # params: pod-replicated (FSDP is on "data")
        bspec = jax.tree_util.tree_map(lambda _: P("pod"),
                                       {"tokens": 0, "labels": 0})
        def _core_manual(*args):
            # declare "pod" manual for constrain() — 0.4.x shard_map has no
            # in-trace manual-axis introspection
            with S.manual_axes({"pod"}):
                return _core(*args)

        specs = dict(in_specs=(pspec, pspec, bspec, P()),
                     out_specs=(pspec, pspec, pspec))
        if hasattr(jax, "shard_map"):
            core = jax.shard_map(_core_manual, mesh=mesh, axis_names={"pod"},
                                 check_vma=False, **specs)
        else:                          # JAX 0.4.x: partial-manual via auto=
            from jax.experimental.shard_map import shard_map
            auto = frozenset(mesh.axis_names) - {"pod"}
            core = shard_map(_core_manual, mesh=mesh, check_rep=False,
                             auto=auto, **specs)
        return core
    return _core


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, *, mode: QuantMode = FP) -> Callable:
    def prefill_step(params, batch):
        # inference: no remat needed (no backward pass)
        return R.apply_forward(params, cfg, batch, mode=mode, remat=False)
    return prefill_step


def make_decode_step(cfg: ArchConfig, *, mode: QuantMode = FP) -> Callable:
    def decode_step(params, batch, cache):
        logits, new_cache = R.apply_decode(params, cfg, batch, cache,
                                           mode=mode)
        return logits, new_cache
    return decode_step


# Static batch-shape ladder: every request batch is padded up to one of
# these, so at most len(BATCH_BUCKETS) + log2(MAX_BUCKET / BATCH_BUCKETS[-1])
# decode-loop compilations ever exist (the deterministic-shapes discipline
# that makes p99 predictable).
BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)

# Hard ceiling of the power-of-two extension past the ladder's end.  An
# unbounded doubling would silently mint new compiled shapes for any batch
# a caller throws at us — precisely the recompile-on-the-hot-path failure
# the ladder exists to rule out.  Batches beyond MAX_BUCKET are a config
# error: raise, don't compile.
MAX_BUCKET = 2048


def bucket_batch(b: int, buckets=BATCH_BUCKETS,
                 max_bucket: int = MAX_BUCKET) -> int:
    """Smallest bucket >= b (powers of two beyond the ladder's end, capped
    at ``max_bucket``).  Raises ValueError past the cap: the bounded shape
    set is the invariant, so oversized batches must be split upstream, not
    absorbed by a fresh compilation."""
    if b <= 0:
        raise ValueError(f"batch must be positive, got {b}")
    for c in buckets:
        if b <= c:
            return c
    c = buckets[-1]
    while c < b and c < max_bucket:
        c *= 2
    if c < b:
        raise ValueError(
            f"batch {b} exceeds MAX_BUCKET={max_bucket}: the static shape "
            f"ladder is bounded by design — split the batch or raise "
            f"MAX_BUCKET deliberately")
    return c


def make_decode_loop(cfg: ArchConfig, *, mode: QuantMode = FP,
                     num_tokens: int, temperature: float = 0.0) -> Callable:
    """Fused multi-token decode: one jit'd ``lax.scan`` over steps.

    Returns ``loop(params, tokens, cache, cache_index, rng=None) ->
    (out, cache)`` with ``tokens`` (B, 1) int32 seed, ``cache_index`` ()
    int32, and ``out`` (B, num_tokens) int32 generated tokens.  With the
    default ``temperature=0.0`` sampling is greedy (``rng`` ignored);
    ``temperature > 0`` draws from :func:`temperature_sample` with a
    per-step key ``fold_in(rng, cache_index + step)`` — the same key
    schedule a per-token Python loop would use, so the fused loop is
    sampling-parity-testable against it.  Compile once per (bucketed
    batch, num_tokens); wrap with :func:`jit_decode_loop` to get the
    cache donated (in-place update, no per-step host round-trip).
    """
    decode = make_decode_step(cfg, mode=mode)

    def loop(params, tokens, cache, cache_index, rng=None):
        if temperature > 0.0 and rng is None:
            raise ValueError(
                "temperature sampling needs an rng key: "
                "loop(params, tokens, cache, cache_index, rng)")

        def step(carry, _):
            tok, cache, idx = carry
            logits, cache = decode(
                params, {"tokens": tok, "cache_index": idx}, cache)
            if temperature > 0.0:
                nxt = temperature_sample(
                    logits, jax.random.fold_in(rng, idx), temperature)
            else:
                nxt = greedy_sample(logits)
            return (nxt[:, None], cache, idx + 1), nxt

        cache_index = jnp.asarray(cache_index, jnp.int32)
        (_, cache, _), toks = jax.lax.scan(
            step, (tokens, cache, cache_index), None, length=num_tokens)
        return jnp.swapaxes(toks, 0, 1), cache

    return loop


def jit_decode_loop(loop: Callable) -> Callable:
    """jit a decode loop with the KV cache donated (argument 2)."""
    return jax.jit(loop, donate_argnums=(2,))


def make_slot_decode_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                          temperature: float = 0.0) -> Callable:
    """One tick of the continuous-batching engine: advance EVERY slot of
    the fixed pool by one token, in one fused step of static shape.

    Returns ``step(params, tokens, cache, slot_index, active) ->
    (next_tokens, cache, slot_index)`` with ``tokens`` (S_slots, 1) int32,
    ``slot_index`` (S_slots,) int32 per-slot sequence positions, and
    ``active`` (S_slots,) bool; with ``temperature > 0`` the step takes a
    trailing ``rng`` key and samples row ``r`` with
    ``fold_in(rng, slot_index[r])`` — the per-row analogue of
    :func:`make_decode_loop`'s key schedule, so engine sampling is
    parity-testable against the fused loop and the per-token reference.

    The active mask folds into sampling (inactive rows emit 0) and into
    the index advance (inactive rows freeze).  Works for every registry
    family with token-only decode (dense/moe/ssm/hybrid): positional KV
    writes are row-local scatters at each slot's own frontier with reads
    masked by ``slot_index``, while non-positional recurrent state is
    frozen for inactive rows through ``registry.mask_inactive_slots`` and
    scrubbed on slot reuse by the families' reset-at-position-0 rule (the
    engine's isolation property test poisons dead rows to prove both).
    Wrap with :func:`jit_slot_decode_step` to donate the cache.
    """
    decode = make_decode_step(cfg, mode=mode)

    def _advance(params, tokens, cache, slot_index, active):
        logits, new_cache = decode(
            params, {"tokens": tokens, "cache_index": slot_index}, cache)
        new_cache = R.mask_inactive_slots(cfg, cache, new_cache, active)
        return logits, new_cache

    def _guard(nxt, logits, active):
        # In-graph finite guard: a row whose last-position logits contain
        # NaN/Inf (corrupted cache, overflowed activation) emits the
        # sentinel -1 instead of a garbage sample, so the host can retire
        # or rebuild the poisoned slot without an extra device round-trip.
        # Valid tokens are >= 0 and inactive rows still emit 0, so the
        # sentinel is unambiguous; with all-finite logits this is the
        # identity and the step stays bit-for-bit what it was.
        finite = jnp.all(jnp.isfinite(logits[:, -1].astype(jnp.float32)),
                         axis=-1)
        nxt = jnp.where(finite, nxt, jnp.full_like(nxt, -1))
        return jnp.where(active, nxt, jnp.zeros_like(nxt))

    if temperature > 0.0:
        def step(params, tokens, cache, slot_index, active, rng):
            logits, cache = _advance(params, tokens, cache, slot_index,
                                     active)
            keys = jax.vmap(lambda p: jax.random.fold_in(rng, p))(slot_index)
            nxt = temperature_sample_rows(logits, keys, temperature)
            nxt = _guard(nxt, logits, active)
            return nxt, cache, slot_index + active.astype(slot_index.dtype)
    else:
        def step(params, tokens, cache, slot_index, active):
            logits, cache = _advance(params, tokens, cache, slot_index,
                                     active)
            nxt = greedy_sample(logits)
            nxt = _guard(nxt, logits, active)
            return nxt, cache, slot_index + active.astype(slot_index.dtype)

    return step


def jit_slot_decode_step(step: Callable) -> Callable:
    """jit a slot decode step with the KV cache donated (argument 2)."""
    return jax.jit(step, donate_argnums=(2,))


def make_verify_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                     k: int, temperature: float = 0.0) -> Callable:
    """One speculative *verify* tick: teacher-force up to ``k + 1`` tokens
    per slot through the target model in ONE fused dispatch, sampling at
    every position — the wide step draft-and-verify acceptance scores
    against.

    Returns ``step(params, tokens, cache, slot_index, n_tokens, active)
    -> (samples, cache, slot_index)`` with ``tokens`` (S, k+1) int32 (per
    row: the slot's next input followed by its draft proposals),
    ``n_tokens`` (S,) int32 how many leading tokens each row really feeds
    (1 for a non-speculating row, up to k+1 for a generating one — one
    compiled shape whatever the mix), and ``samples`` (S, k+1) int32 the
    sample drawn after each fed position.  With ``temperature > 0`` the
    step takes a trailing ``rng`` and samples position ``p`` of row ``r``
    with ``fold_in(rng, slot_index[r] + p)`` — the position-derived key
    schedule of :func:`make_slot_decode_step`, which is what makes
    *sampled* speculative acceptance bitwise, not just greedy.

    Internally this is a ``lax.scan`` of the SAME per-token slot decode
    step (per-row masking, paged block tables, recurrent freeze — all
    inherited), so ``samples[r, j]`` is bit-for-bit what ``j + 1``
    non-speculative ticks would have produced given the same fed tokens.
    Positions past ``n_tokens[r]`` keep row ``r``'s index frozen; their
    writes land at the row's frozen frontier and are overwritten by the
    next real feed before any read can see them (the engine's rewind
    rule — see docs/serving.md).  Rows with non-finite logits at any fed
    position emit the -1 sentinel there, and the engine treats the whole
    round as uncommitted.  Wrap with :func:`jit_verify_step` to donate
    the cache.
    """
    decode = make_decode_step(cfg, mode=mode)

    def _scan(params, tokens, cache, slot_index, n_tokens, active, rng):
        def body(carry, inp):
            cache, idx = carry
            tok, j = inp                        # tok (S,), j ()
            act = active & (j < n_tokens)
            logits, new_cache = decode(
                params, {"tokens": tok[:, None], "cache_index": idx}, cache)
            new_cache = R.mask_inactive_slots(cfg, cache, new_cache, act)
            if temperature > 0.0:
                keys = jax.vmap(lambda p: jax.random.fold_in(rng, p))(idx)
                nxt = temperature_sample_rows(logits, keys, temperature)
            else:
                nxt = greedy_sample(logits)
            finite = jnp.all(
                jnp.isfinite(logits[:, -1].astype(jnp.float32)), axis=-1)
            nxt = jnp.where(finite, nxt, jnp.full_like(nxt, -1))
            nxt = jnp.where(act, nxt, jnp.zeros_like(nxt))
            return (new_cache, idx + act.astype(idx.dtype)), nxt

        (cache, idx), samples = jax.lax.scan(
            body, (cache, slot_index),
            (jnp.swapaxes(tokens, 0, 1), jnp.arange(k + 1)))
        return jnp.swapaxes(samples, 0, 1), cache, idx

    if temperature > 0.0:
        def step(params, tokens, cache, slot_index, n_tokens, active, rng):
            return _scan(params, tokens, cache, slot_index, n_tokens,
                         active, rng)
    else:
        def step(params, tokens, cache, slot_index, n_tokens, active):
            return _scan(params, tokens, cache, slot_index, n_tokens,
                         active, None)
    return step


def jit_verify_step(step: Callable) -> Callable:
    """jit a verify step with the KV cache donated (argument 2)."""
    return jax.jit(step, donate_argnums=(2,))


def make_draft_propose_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                            k: int) -> Callable:
    """One speculative *propose* tick: the draft model extends every
    speculating slot by ``k`` greedy tokens in one fused dispatch.

    Returns ``step(params, tokens, cache, slot_index, active) ->
    (proposals, cache, slot_index)`` with ``tokens`` (S, 1) int32 each
    row's committed next input and ``proposals`` (S, k) int32 the draft's
    greedy continuations ``d_1..d_k`` (fed back token by token).  The
    draft is always greedy whatever the target's sampling mode: its
    proposals are *guesses* the verify step scores, so they affect only
    the acceptance rate, never the committed output.  A draft row whose
    logits go non-finite proposes token 0 instead of the -1 sentinel —
    a wrong-but-harmless guess (it can only be rejected), which is why
    draft dispatches need none of the engine's fault recovery.  Wrap
    with :func:`jit_draft_propose_step` to donate the draft cache.
    """
    decode = make_decode_step(cfg, mode=mode)

    def step(params, tokens, cache, slot_index, active):
        def body(carry, _):
            tok, cache, idx = carry
            logits, new_cache = decode(
                params, {"tokens": tok, "cache_index": idx}, cache)
            new_cache = R.mask_inactive_slots(cfg, cache, new_cache, active)
            nxt = greedy_sample(logits)
            finite = jnp.all(
                jnp.isfinite(logits[:, -1].astype(jnp.float32)), axis=-1)
            nxt = jnp.where(finite & active, nxt, jnp.zeros_like(nxt))
            return (nxt[:, None], new_cache,
                    idx + active.astype(idx.dtype)), nxt

        (_, cache, idx), props = jax.lax.scan(
            body, (tokens, cache, slot_index), None, length=k)
        return jnp.swapaxes(props, 0, 1), cache, idx

    return step


def jit_draft_propose_step(step: Callable) -> Callable:
    """jit a propose step with the draft cache donated (argument 2)."""
    return jax.jit(step, donate_argnums=(2,))


def make_prefill_chunk_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                            chunk: int) -> Callable:
    """Chunked prefill for ONE slot of the engine's pool: write ``chunk``
    teacher-forced prompt tokens of KV/recurrent state in a single
    dispatch, instead of one engine tick per token.

    Returns ``step(params, tokens, cache, sid, start, n_valid) -> cache``
    with ``tokens`` (chunk,) int32 prompt tokens, ``sid`` () int32 the
    slot row, ``start`` () int32 the slot's current frontier, and
    ``n_valid`` () int32 how many of the ``chunk`` tokens are real (the
    rest is bucket padding whose state updates are reverted, so one
    compilation per bucket on :func:`bucket_batch`'s power-of-two ladder
    serves every prompt length).

    Internally this slices the slot's row out of the pooled cache
    (``registry.cache_batch_axes`` names the slot axis per leaf), runs a
    ``lax.scan`` of the SAME per-token decode step the engine and the
    sequential reference use — so the written state is bit-for-bit what
    per-token prefill would have written — and scatters the row back.
    Logits are discarded: the engine feeds the LAST prompt token through
    the fused slot step, whose sample is the request's first output.
    Wrap with :func:`jit_prefill_chunk_step` to donate the cache.
    """
    decode = make_decode_step(cfg, mode=mode)
    # prime families decode with a (1,)-vector index inside the chunk
    # scan: the per-row path is where their xlen frontier masks the
    # padded source, and the fused slot step takes exactly that path —
    # token-only families keep the scalar (lockstep) variant bit-for-bit
    vec_index = R.needs_prime(cfg)

    def _scan_slot(params, tokens, slot, start, n_valid):
        def body(carry, inp):
            slot, idx = carry
            tok, i = inp
            _, new_slot = decode(
                params, {"tokens": tok.reshape(1, 1),
                         "cache_index": (idx.reshape(1) if vec_index
                                         else idx)},
                slot)
            keep = i < n_valid
            slot = jax.tree_util.tree_map(
                lambda n, o: jnp.where(keep, n, o), new_slot, slot)
            return (slot, jnp.where(keep, idx + 1, idx)), None

        (slot, _), _ = jax.lax.scan(
            body, (slot, jnp.asarray(start, jnp.int32)),
            (tokens, jnp.arange(chunk)))
        return slot

    def step(params, tokens, cache, sid, start, n_valid):
        if "block_tables" in cache:
            return _paged_step(params, tokens, cache, sid, start, n_valid)
        axes = R.cache_batch_axes(cfg, cache)
        slot = {k: jax.lax.dynamic_slice_in_dim(v, sid, 1, axis=axes[k])
                for k, v in cache.items()}
        slot = _scan_slot(params, tokens, slot, start, n_valid)
        return {k: jax.lax.dynamic_update_slice_in_dim(
                    cache[k], slot[k], sid, axis=axes[k])
                for k in cache}

    def _paged_step(params, tokens, cache, sid, start, n_valid):
        # Paged cache: gather the slot's logical row through its block
        # table into a CONTIGUOUS one-slot view (bit-identical bytes to
        # what the contiguous engine would hold), run the same per-token
        # scan on it, then scatter the row's blocks back at the table's
        # physical entries.  Unwritten/shared table entries are rewritten
        # with the bytes just gathered — byte-identical, so shared blocks
        # are never mutated and duplicate trash entries (block 0) all
        # write the same block-0 content back.
        axes = R.cache_batch_axes(cfg, cache)
        paxes = R.paged_block_axes(cfg, cache)
        trow = jax.lax.dynamic_slice_in_dim(
            cache["block_tables"], sid, 1, axis=0)[0]       # (MB,) int32
        slot = {}
        for k, v in cache.items():
            if k == "block_tables":
                continue
            a = paxes.get(k)
            if a is None:                    # slot-resident leaf (xk/xv/xlen)
                slot[k] = jax.lax.dynamic_slice_in_dim(v, sid, 1,
                                                       axis=axes[k])
            else:                            # paged leaf: gather via table
                gat = jnp.take(v, trow, axis=a)     # (..., MB, bs, ...)
                shp = (gat.shape[:a] + (gat.shape[a] * gat.shape[a + 1],)
                       + gat.shape[a + 2:])
                slot[k] = jnp.expand_dims(gat.reshape(shp), axis=a)
        # the inner decode sees a contiguous (no-table) slot view, so it
        # takes the exact same write/mask path as the contiguous engine
        slot = _scan_slot(params, tokens, slot, start, n_valid)
        out = dict(cache)
        for k, v in cache.items():
            if k == "block_tables":
                continue
            a = paxes.get(k)
            if a is None:
                out[k] = jax.lax.dynamic_update_slice_in_dim(
                    v, slot[k], sid, axis=axes[k])
            else:
                row = jnp.squeeze(slot[k], axis=a)
                shp = (row.shape[:a] + (trow.shape[0], v.shape[a + 1])
                       + row.shape[a + 1:])
                blocks = row.reshape(shp)
                out[k] = v.at[(slice(None),) * a + (trow,)].set(blocks)
        return out

    return step


def jit_prefill_chunk_step(step: Callable) -> Callable:
    """jit a prefill chunk step with the KV cache donated (argument 2)."""
    return jax.jit(step, donate_argnums=(2,))


def make_prime_step(cfg: ArchConfig, *, mode: QuantMode = FP) -> Callable:
    """Prime dispatch for ONE slot of the engine's pool: run the request's
    encoder / vision tower once and scatter the pre-projected cross-K/V
    (plus the row's ``xlen`` frontier) into the slot's row of the pooled
    cache — the second slot-resident static operand that lets encdec/vlm
    decode through the same fused slot step as every other family.

    Returns ``step(params, source, cache, sid, n_valid) -> cache`` with
    ``source`` (1, source_len(cfg), D) the request's frame/patch
    embeddings padded to the static source length, ``sid`` () int32 the
    slot row, and ``n_valid`` () int32 how many source positions are
    real.  Decode masks cross reads at the frontier, so K/V past
    ``n_valid`` — pad projections, or a previous tenant's stale tail —
    is never read.  The pad itself is deterministic zero frames: the
    vlm's position-wise projections are pad-exact, while the encdec
    encoder attends over the padded input like Whisper encodes its
    pad-to-30s silence (both the engine and the sequential reference
    prime with byte-identical padded sources, so the semantics is one
    and parity is exact).  One static shape, one compilation, like every
    other engine dispatch.  Wrap with :func:`jit_prime_step` to donate
    the cache.
    """

    def step(params, source, cache, sid, n_valid):
        leaves = R.prime_slot(cfg, params, source, n_valid, mode=mode)
        axes = R.cache_batch_axes(cfg, cache)
        out = dict(cache)
        for k, v in leaves.items():
            out[k] = jax.lax.dynamic_update_slice_in_dim(
                cache[k], v.astype(cache[k].dtype), sid, axis=axes[k])
        return out

    return step


def jit_prime_step(step: Callable) -> Callable:
    """jit a prime step with the pooled cache donated (argument 2)."""
    return jax.jit(step, donate_argnums=(2,))


# ---------------------------------------------------------------------------
# per-model compiled step cache (the multiplexed engine's step registry)
# ---------------------------------------------------------------------------
#
# A multiplexed engine holds one compiled step SET per admitted model, and
# the differential test harness additionally builds dedicated single-model
# engines over the very same configs.  Memoizing the jitted builders on
# their full specialization key — (kind, cfg, mode, static shape args);
# both ArchConfig and QuantMode are frozen dataclasses, hence hashable —
# means each (model, shape) pair compiles exactly once per process however
# many Engine instances reference it.  Params stay call arguments, so
# sharing a compiled step between engines shares no model state.

_STEP_CACHE: dict = {}


def _cached(key, build):
    fn = _STEP_CACHE.get(key)
    if fn is None:
        fn = _STEP_CACHE[key] = build()
    return fn


def cached_slot_decode_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                            temperature: float = 0.0) -> Callable:
    """Memoized ``jit_slot_decode_step(make_slot_decode_step(...))``."""
    return _cached(("slot_decode", cfg, mode, temperature),
                   lambda: jit_slot_decode_step(make_slot_decode_step(
                       cfg, mode=mode, temperature=temperature)))


def cached_prefill_chunk_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                              chunk: int) -> Callable:
    """Memoized ``jit_prefill_chunk_step(make_prefill_chunk_step(...))``."""
    return _cached(("prefill_chunk", cfg, mode, chunk),
                   lambda: jit_prefill_chunk_step(make_prefill_chunk_step(
                       cfg, mode=mode, chunk=chunk)))


def cached_prime_step(cfg: ArchConfig, *, mode: QuantMode = FP) -> Callable:
    """Memoized ``jit_prime_step(make_prime_step(...))``."""
    return _cached(("prime", cfg, mode),
                   lambda: jit_prime_step(make_prime_step(cfg, mode=mode)))


def cached_verify_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                       k: int, temperature: float = 0.0) -> Callable:
    """Memoized ``jit_verify_step(make_verify_step(...))``."""
    return _cached(("verify", cfg, mode, k, temperature),
                   lambda: jit_verify_step(make_verify_step(
                       cfg, mode=mode, k=k, temperature=temperature)))


def cached_draft_propose_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                              k: int) -> Callable:
    """Memoized ``jit_draft_propose_step(make_draft_propose_step(...))``."""
    return _cached(("draft_propose", cfg, mode, k),
                   lambda: jit_draft_propose_step(make_draft_propose_step(
                       cfg, mode=mode, k=k)))


# ---------------------------------------------------------------------------
# tensor-parallel (sharded) serving steps
# ---------------------------------------------------------------------------
#
# The engine's second ExecutorBackend: the SAME make_*_step builders run
# under full-manual shard_map on a ("model",) host mesh, sharded along
# the SLOT axis.  Every per-row float op of the fused steps is
# batch-size-independent (no op ever crosses rows), so a shard advancing
# its num_slots/tp rows computes bit-for-bit what the single-device step
# computes for those rows — which is the whole point: head/expert tensor
# parallelism needs a cross-shard psum whose float adds reassociate, and
# bit parity with the single-device engine (the repo's gating currency)
# would be lost.  Slot sharding costs no collectives at all, which also
# keeps us inside the XLA 0.4.x-safe subset: the partitioner bundled
# with JAX 0.4.x aborts on all-gather/ppermute under shard_map even in
# forward-only code (and on any scan backward — see
# supports_int8_grad_exchange), but forward scans with zero collectives
# partition fine.
#
# Paged leaves are the one wrinkle: physical KV blocks are shared across
# slots (hence across shards), so each shard gets a replicated COPY,
# diverges it with its own rows' writes, and the merge outside the
# shard_map folds the copies back by "who changed it" — sound because
# every real block has at most one writing slot per tick (block tables
# partition real blocks; only reserved trash block 0 takes multi-shard
# garbage writes, and block 0 is never read).

def supports_sharded_serving() -> bool:
    """True when the installed JAX can run the sharded serving steps.

    The serving twin of :func:`supports_int8_grad_exchange`, with a
    weaker requirement: the steps are forward-only and collective-free,
    so the 0.4.x partitioner handles them — we only need
    ``jax.experimental.shard_map`` to exist."""
    try:
        from jax.experimental.shard_map import shard_map  # noqa: F401
    except ImportError:
        return False
    return True


@functools.lru_cache(maxsize=None)
def _sharded_mesh(tp: int):
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh((tp,), ("model",))


def _shard_map(tp: int):
    from jax.experimental.shard_map import shard_map
    return functools.partial(shard_map, mesh=_sharded_mesh(tp),
                             check_rep=False)


def _sharded_cache_specs(cfg: ArchConfig, cache: dict):
    """Per-leaf shard_map specs for a pooled cache: slot-resident leaves
    shard on their slot axis, the block table on its slot axis 0, paged
    block leaves replicate in (each shard diverges a private copy) and
    come back STACKED (leading shard axis) for the host-side merge.

    Returns ``(in_specs, out_specs, paged_keys, axes)``."""
    from jax.sharding import PartitionSpec as P
    axes = R.cache_batch_axes(cfg, cache)
    paxes = R.paged_block_axes(cfg, cache) if "block_tables" in cache \
        else {}
    in_s, out_s, paged = {}, {}, []
    for k in cache:
        if k == "block_tables":
            in_s[k] = out_s[k] = P("model")
        elif paxes.get(k) is not None:
            in_s[k] = P()
            out_s[k] = P("model")          # leaf[None] per shard
            paged.append(k)
        else:
            sp = P(*([None] * axes[k] + ["model"]))
            in_s[k] = out_s[k] = sp
    return in_s, out_s, paged, axes


def _bitwise_neq(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise "did the bytes change": float leaves compare as
    integer bit patterns so a write of 0.0 over -0.0 (equal under IEEE
    ``!=``) still counts as a write — the merge below must be exact to
    the BIT, not to float equality (NaN != NaN would also misfire)."""
    if jnp.issubdtype(a.dtype, jnp.floating):
        w = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}[a.dtype.itemsize]
        return (jax.lax.bitcast_convert_type(a, w)
                != jax.lax.bitcast_convert_type(b, w))
    return a != b


def _merge_shard_writes(stacked: jax.Array, old: jax.Array) -> jax.Array:
    """Fold per-shard copies of a replicated paged leaf: wherever shard
    i's bytes differ from the pre-step bytes, shard i wrote there.  At
    most one shard writes any real block per tick (block tables
    partition real blocks across slots), so the fold order only decides
    who wins the reserved trash block 0 — which is never read."""
    acc = old
    for i in range(stacked.shape[0]):
        si = stacked[i]
        acc = jnp.where(_bitwise_neq(si, old), si, acc)
    return acc


def _local_slots(cache: dict, axes: dict, paged_keys) -> int:
    """This shard's slot count, read off a slot-resident leaf's shape
    (inside shard_map every leaf is already the local block)."""
    if "block_tables" in cache:
        return cache["block_tables"].shape[0]
    for k, v in cache.items():
        if k not in paged_keys:
            return v.shape[axes[k]]
    raise ValueError("cache has no slot-resident leaf")


class _StructMemo:
    """jit-compiled sharded step per cache STRUCTURE (leaf names + slot
    axes): the engine's cache structure is fixed per lane, so this holds
    one entry per (lane family, paged-ness) — the same bounded-compile
    discipline as the batch ladder."""

    def __init__(self, build):
        self.build = build
        self.fns: dict = {}

    def __call__(self, cfg, cache):
        axes = R.cache_batch_axes(cfg, cache)
        key = (tuple(sorted(cache)), tuple(sorted(axes.items())))
        fn = self.fns.get(key)
        if fn is None:
            fn = self.fns[key] = self.build(cfg, cache)
        return fn


def _rep_and_row(tp: int):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = _sharded_mesh(tp)
    return NamedSharding(mesh, P()), NamedSharding(mesh, P("model"))


def make_sharded_slot_decode_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                                  temperature: float = 0.0,
                                  tp: int = 1) -> Callable:
    """Tensor-parallel :func:`make_slot_decode_step`: same signature,
    bit-identical outputs, each shard advancing ``num_slots / tp`` rows
    with the params replicated.  The pool size must divide by ``tp``
    (``ShardedExecutor.validate`` enforces it)."""
    base = make_slot_decode_step(cfg, mode=mode, temperature=temperature)
    has_rng = temperature > 0.0

    def build(cfg_, cache0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        in_c, out_c, paged, _ = _sharded_cache_specs(cfg_, cache0)
        row = P("model")

        def inner(params, tokens, cache, slot_index, active, *rng):
            with S.manual_axes({"model"}):
                nxt, new_cache, idx = base(params, tokens, cache,
                                           slot_index, active, *rng)
            new_cache = {k: (v[None] if k in paged else v)
                         for k, v in new_cache.items()}
            return nxt, new_cache, idx

        in_specs = (P(), row, in_c, row, row) + ((P(),) if has_rng else ())
        fn = _shard_map(tp)(inner, in_specs=in_specs,
                            out_specs=(row, out_c, row))

        if has_rng:
            def outer(params, tokens, cache, slot_index, active, rng):
                nxt, nc, idx = fn(params, tokens, cache, slot_index,
                                  active, rng)
                for k in paged:
                    nc[k] = _merge_shard_writes(nc[k], cache[k])
                return nxt, nc, idx
        else:
            def outer(params, tokens, cache, slot_index, active):
                nxt, nc, idx = fn(params, tokens, cache, slot_index,
                                  active)
                for k in paged:
                    nc[k] = _merge_shard_writes(nc[k], cache[k])
                return nxt, nc, idx

        rep, rowsh = _rep_and_row(tp)
        mesh = _sharded_mesh(tp)
        csh_in = {k: NamedSharding(mesh, s) for k, s in in_c.items()}
        csh_out = {k: (rep if k in paged else NamedSharding(mesh, out_c[k]))
                   for k in out_c}
        in_sh = (rep, rowsh, csh_in, rowsh, rowsh) \
            + ((rep,) if has_rng else ())
        # no donation: the paged merge reads the pre-step cache bytes,
        # so the buffer cannot be reused in place (and the non-paged
        # case keeps the same policy for one uniform compile path)
        return jax.jit(outer, in_shardings=in_sh,
                       out_shardings=(rowsh, csh_out, rowsh))

    memo = _StructMemo(build)

    if has_rng:
        def step(params, tokens, cache, slot_index, active, rng):
            return memo(cfg, cache)(params, tokens, cache, slot_index,
                                    active, rng)
    else:
        def step(params, tokens, cache, slot_index, active):
            return memo(cfg, cache)(params, tokens, cache, slot_index,
                                    active)
    return step


def make_sharded_verify_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                             k: int, temperature: float = 0.0,
                             tp: int = 1) -> Callable:
    """Tensor-parallel :func:`make_verify_step` — the wide speculative
    verify scan, slot-axis sharded.  The scan is forward-only and
    collective-free, so it stays inside the 0.4.x-safe subset."""
    base = make_verify_step(cfg, mode=mode, k=k, temperature=temperature)
    has_rng = temperature > 0.0

    def build(cfg_, cache0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        in_c, out_c, paged, _ = _sharded_cache_specs(cfg_, cache0)
        row = P("model")

        def inner(params, tokens, cache, slot_index, n_tokens, active,
                  *rng):
            with S.manual_axes({"model"}):
                samples, new_cache, idx = base(params, tokens, cache,
                                               slot_index, n_tokens,
                                               active, *rng)
            new_cache = {kk: (v[None] if kk in paged else v)
                         for kk, v in new_cache.items()}
            return samples, new_cache, idx

        in_specs = (P(), row, in_c, row, row, row) \
            + ((P(),) if has_rng else ())
        fn = _shard_map(tp)(inner, in_specs=in_specs,
                            out_specs=(row, out_c, row))

        if has_rng:
            def outer(params, tokens, cache, slot_index, n_tokens,
                      active, rng):
                samples, nc, idx = fn(params, tokens, cache, slot_index,
                                      n_tokens, active, rng)
                for kk in paged:
                    nc[kk] = _merge_shard_writes(nc[kk], cache[kk])
                return samples, nc, idx
        else:
            def outer(params, tokens, cache, slot_index, n_tokens,
                      active):
                samples, nc, idx = fn(params, tokens, cache, slot_index,
                                      n_tokens, active)
                for kk in paged:
                    nc[kk] = _merge_shard_writes(nc[kk], cache[kk])
                return samples, nc, idx

        rep, rowsh = _rep_and_row(tp)
        mesh = _sharded_mesh(tp)
        csh_in = {kk: NamedSharding(mesh, s) for kk, s in in_c.items()}
        csh_out = {kk: (rep if kk in paged
                        else NamedSharding(mesh, out_c[kk]))
                   for kk in out_c}
        in_sh = (rep, rowsh, csh_in, rowsh, rowsh, rowsh) \
            + ((rep,) if has_rng else ())
        return jax.jit(outer, in_shardings=in_sh,
                       out_shardings=(rowsh, csh_out, rowsh))

    memo = _StructMemo(build)

    if has_rng:
        def step(params, tokens, cache, slot_index, n_tokens, active, rng):
            return memo(cfg, cache)(params, tokens, cache, slot_index,
                                    n_tokens, active, rng)
    else:
        def step(params, tokens, cache, slot_index, n_tokens, active):
            return memo(cfg, cache)(params, tokens, cache, slot_index,
                                    n_tokens, active)
    return step


def make_sharded_draft_propose_step(cfg: ArchConfig, *,
                                    mode: QuantMode = FP, k: int,
                                    tp: int = 1) -> Callable:
    """Tensor-parallel :func:`make_draft_propose_step`.  The draft cache
    is always contiguous (never paged), so this is pure slot sharding
    with no merge."""
    base = make_draft_propose_step(cfg, mode=mode, k=k)

    def build(cfg_, cache0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        in_c, out_c, paged, _ = _sharded_cache_specs(cfg_, cache0)
        row = P("model")

        def inner(params, tokens, cache, slot_index, active):
            with S.manual_axes({"model"}):
                props, new_cache, idx = base(params, tokens, cache,
                                             slot_index, active)
            new_cache = {kk: (v[None] if kk in paged else v)
                         for kk, v in new_cache.items()}
            return props, new_cache, idx

        fn = _shard_map(tp)(inner, in_specs=(P(), row, in_c, row, row),
                            out_specs=(row, out_c, row))

        def outer(params, tokens, cache, slot_index, active):
            props, nc, idx = fn(params, tokens, cache, slot_index, active)
            for kk in paged:
                nc[kk] = _merge_shard_writes(nc[kk], cache[kk])
            return props, nc, idx

        rep, rowsh = _rep_and_row(tp)
        mesh = _sharded_mesh(tp)
        csh_in = {kk: NamedSharding(mesh, s) for kk, s in in_c.items()}
        csh_out = {kk: (rep if kk in paged
                        else NamedSharding(mesh, out_c[kk]))
                   for kk in out_c}
        return jax.jit(outer,
                       in_shardings=(rep, rowsh, csh_in, rowsh, rowsh),
                       out_shardings=(rowsh, csh_out, rowsh))

    memo = _StructMemo(build)

    def step(params, tokens, cache, slot_index, active):
        return memo(cfg, cache)(params, tokens, cache, slot_index, active)
    return step


def make_sharded_prefill_chunk_step(cfg: ArchConfig, *,
                                    mode: QuantMode = FP, chunk: int,
                                    tp: int = 1) -> Callable:
    """Tensor-parallel :func:`make_prefill_chunk_step`: a single-slot
    dispatch, so exactly ONE shard owns the target row.  Every shard
    runs the base step on its clamped local row (static shapes — no
    shard may skip work); the owner's writes are kept via the in-range
    mask, and for paged leaves the owner's whole diverged copy is
    selected outside the shard_map (non-owners corrupted a wrong local
    row's blocks in their private copies, which are discarded)."""
    base = make_prefill_chunk_step(cfg, mode=mode, chunk=chunk)

    def build(cfg_, cache0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        in_c, out_c, paged, axes = _sharded_cache_specs(cfg_, cache0)

        def inner(params, tokens, cache, sid, start, n_valid):
            local_S = _local_slots(cache, axes, paged)
            off = jax.lax.axis_index("model") * local_S
            lsid = sid - off
            in_r = (lsid >= 0) & (lsid < local_S)
            lsid_c = jnp.clip(lsid, 0, local_S - 1)
            with S.manual_axes({"model"}):
                new_cache = base(params, tokens, cache, lsid_c, start,
                                 n_valid)
            out = {}
            for kk, v in new_cache.items():
                if kk in paged:
                    out[kk] = v[None]
                else:
                    out[kk] = jnp.where(in_r, v, cache[kk])
            return out

        fn = _shard_map(tp)(inner,
                            in_specs=(P(), P(), in_c, P(), P(), P()),
                            out_specs=out_c)

        def outer(params, tokens, cache, sid, start, n_valid):
            nc = fn(params, tokens, cache, sid, start, n_valid)
            if paged:
                local_S = _global_slots(cfg_, cache, axes, paged) // tp
                owner = jnp.asarray(sid, jnp.int32) // local_S
                for kk in paged:
                    nc[kk] = jax.lax.dynamic_index_in_dim(
                        nc[kk], owner, 0, keepdims=False)
            return nc

        rep, _ = _rep_and_row(tp)
        mesh = _sharded_mesh(tp)
        csh_in = {kk: NamedSharding(mesh, s) for kk, s in in_c.items()}
        csh_out = {kk: (rep if kk in paged
                        else NamedSharding(mesh, out_c[kk]))
                   for kk in out_c}
        return jax.jit(outer,
                       in_shardings=(rep, rep, csh_in, rep, rep, rep),
                       out_shardings=csh_out)

    memo = _StructMemo(build)

    def step(params, tokens, cache, sid, start, n_valid):
        return memo(cfg, cache)(params, tokens, cache, sid, start, n_valid)
    return step


def _global_slots(cfg: ArchConfig, cache: dict, axes: dict,
                  paged_keys) -> int:
    """Global pool size, read off an UNsharded cache (host side)."""
    if "block_tables" in cache:
        return cache["block_tables"].shape[0]
    for k, v in cache.items():
        if k not in paged_keys:
            return v.shape[axes[k]]
    raise ValueError("cache has no slot-resident leaf")


def make_sharded_prime_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                            tp: int = 1) -> Callable:
    """Tensor-parallel :func:`make_prime_step`.  Prime writes only
    slot-resident leaves (cross K/V + xlen), so each shard runs the
    encoder replicated, scatters into its clamped local row, and the
    in-range mask keeps the owner's write — no paged merge needed."""
    base = make_prime_step(cfg, mode=mode)

    def build(cfg_, cache0):
        from jax.sharding import NamedSharding, PartitionSpec as P
        in_c, out_c_all, paged, axes = _sharded_cache_specs(cfg_, cache0)
        # prime never touches paged leaves: pass them through untouched
        # and REPLICATED (every shard returns identical bytes, so the
        # unchecked-replication out_spec is valid) — no stack, no merge
        out_c = {k: (P() if k in paged else out_c_all[k])
                 for k in out_c_all}
        in_cp = {k: (P() if k in paged else in_c[k]) for k in in_c}

        def inner(params, source, cache, sid, n_valid):
            local_S = _local_slots(cache, axes, paged)
            off = jax.lax.axis_index("model") * local_S
            lsid = sid - off
            in_r = (lsid >= 0) & (lsid < local_S)
            lsid_c = jnp.clip(lsid, 0, local_S - 1)
            with S.manual_axes({"model"}):
                new_cache = base(params, source, cache, lsid_c, n_valid)
            return {k: (v if k in paged
                        else jnp.where(in_r, v, cache[k]))
                    for k, v in new_cache.items()}

        fn = _shard_map(tp)(inner, in_specs=(P(), P(), in_cp, P(), P()),
                            out_specs=out_c)

        def outer(params, source, cache, sid, n_valid):
            return fn(params, source, cache, sid, n_valid)

        rep, _ = _rep_and_row(tp)
        mesh = _sharded_mesh(tp)
        csh_in = {k: NamedSharding(mesh, s) for k, s in in_cp.items()}
        csh_out = {k: NamedSharding(mesh, s) for k, s in out_c.items()}
        return jax.jit(outer,
                       in_shardings=(rep, rep, csh_in, rep, rep),
                       out_shardings=csh_out)

    memo = _StructMemo(build)

    def step(params, source, cache, sid, n_valid):
        return memo(cfg, cache)(params, source, cache, sid, n_valid)
    return step


def cached_sharded_slot_decode_step(cfg: ArchConfig, *,
                                    mode: QuantMode = FP,
                                    temperature: float = 0.0,
                                    tp: int = 1) -> Callable:
    """Memoized :func:`make_sharded_slot_decode_step` (key includes tp)."""
    return _cached(("sharded_slot_decode", cfg, mode, temperature, tp),
                   lambda: make_sharded_slot_decode_step(
                       cfg, mode=mode, temperature=temperature, tp=tp))


def cached_sharded_prefill_chunk_step(cfg: ArchConfig, *,
                                      mode: QuantMode = FP, chunk: int,
                                      tp: int = 1) -> Callable:
    """Memoized :func:`make_sharded_prefill_chunk_step`."""
    return _cached(("sharded_prefill_chunk", cfg, mode, chunk, tp),
                   lambda: make_sharded_prefill_chunk_step(
                       cfg, mode=mode, chunk=chunk, tp=tp))


def cached_sharded_prime_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                              tp: int = 1) -> Callable:
    """Memoized :func:`make_sharded_prime_step`."""
    return _cached(("sharded_prime", cfg, mode, tp),
                   lambda: make_sharded_prime_step(cfg, mode=mode, tp=tp))


def cached_sharded_verify_step(cfg: ArchConfig, *, mode: QuantMode = FP,
                               k: int, temperature: float = 0.0,
                               tp: int = 1) -> Callable:
    """Memoized :func:`make_sharded_verify_step`."""
    return _cached(("sharded_verify", cfg, mode, k, temperature, tp),
                   lambda: make_sharded_verify_step(
                       cfg, mode=mode, k=k, temperature=temperature, tp=tp))


def cached_sharded_draft_propose_step(cfg: ArchConfig, *,
                                      mode: QuantMode = FP, k: int,
                                      tp: int = 1) -> Callable:
    """Memoized :func:`make_sharded_draft_propose_step`."""
    return _cached(("sharded_draft_propose", cfg, mode, k, tp),
                   lambda: make_sharded_draft_propose_step(
                       cfg, mode=mode, k=k, tp=tp))


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def temperature_sample(logits: jax.Array, rng: jax.Array,
                       temperature: float = 1.0) -> jax.Array:
    return jax.random.categorical(
        rng, logits[:, -1].astype(jnp.float32) / temperature
    ).astype(jnp.int32)


def temperature_sample_rows(logits: jax.Array, keys: jax.Array,
                            temperature: float = 1.0) -> jax.Array:
    """Per-row temperature sampling: row ``r`` draws with ``keys[r]``.

    This is the slot engine's schedule — every row is an independent
    request at its own position, so each gets its own
    ``fold_in(rng, position)`` key.  A single row's draw is bitwise equal
    to :func:`temperature_sample` at batch 1 with the same key (the
    categorical consumes the same random bits), which is what makes
    engine sampling parity-testable against the sequential reference."""
    last = logits[:, -1].astype(jnp.float32) / temperature
    return jax.vmap(jax.random.categorical)(keys, last).astype(jnp.int32)


# ---------------------------------------------------------------------------
# jit + sharding assembly (used by launch/ and the dry-run)
# ---------------------------------------------------------------------------

def shard_train_fn(train_step, params_like, opt_like, batch_like, mesh,
                   rules):
    """jit with in/out shardings resolved from the rules."""
    p_sh = S.tree_shardings(params_like, mesh, rules)
    o_sh = S.tree_shardings(opt_like, mesh, rules)
    from jax.sharding import NamedSharding
    b_sh = jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, S.batch_spec(mesh, max(1, x.ndim))),
        batch_like)
    r_sh = NamedSharding(mesh, S.batch_spec(mesh, 1))
    return jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, b_sh, r_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
