"""Step watchdog: straggler detection + training-loop fault handling.

With static shapes and deterministic execution (no data-dependent
recompiles), per-step wall time is tight — the TPU paper's determinism
argument.  That makes straggler detection trivial and reliable: a step
slower than ``threshold`` x the rolling median indicates a sick host /
preemption, not workload variance.

The watchdog is pure bookkeeping (works identically under simulation in
tests): the launcher decides the response (log, checkpoint-now, or abort
for the scheduler to restart — which `--resume auto` then recovers).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import List, Optional


@dataclasses.dataclass
class StepWatchdog:
    window: int = 32
    threshold: float = 2.0
    warmup_steps: int = 3          # ignore compile-dominated first steps
    # who this watchdog watches: a multi-replica run (engine/router.py)
    # labels each engine's watchdog so straggler warnings attribute to
    # the right replica instead of an anonymous "engine tick N"
    name: Optional[str] = None
    _times: List[float] = dataclasses.field(default_factory=list)
    _seen: int = 0
    slow_steps: int = 0

    def record(self, step_seconds: float) -> Optional[str]:
        """Record a step time; returns a warning string for stragglers."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return None
        if len(self._times) >= 8:
            med = statistics.median(self._times)
            if step_seconds > self.threshold * med:
                self.slow_steps += 1
                tag = f"[{self.name}] " if self.name else ""
                return (f"{tag}straggler: step took {step_seconds:.3f}s "
                        f"({step_seconds / med:.1f}x median {med:.3f}s)")
        self._times.append(step_seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        return None

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0


class StepTimer:
    def __init__(self):
        self._t0 = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self._t0
        return False
