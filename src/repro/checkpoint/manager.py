"""Sharded checkpointing with a crash-safe commit protocol.

Fault-tolerance requirements (DESIGN.md §4) and how they're met:

- **Atomicity**: checkpoints are written to ``step_XXXX.tmp/`` and renamed
  to ``step_XXXX/`` only after every array + the manifest are fsync'd; a
  ``COMMITTED`` marker is written last.  Restore only considers directories
  with the marker, so a host dying mid-save can never corrupt restore.
- **Integrity**: the manifest stores a per-leaf SHA-256 digest; restore
  verifies (cheap relative to I/O) and raises on mismatch.
- **Mesh-elasticity**: arrays are saved in *logical* (unsharded) layout via
  ``jax.device_get``; on restore they are resharded to whatever mesh/rules
  are active — restart on 192 or 512 chips works (elastic re-mesh).
- **Async**: ``CheckpointManager.save_async`` snapshots to host memory on
  the critical path, then writes on a background thread (the train loop
  only blocks if a previous save is still in flight).
- **Retention**: keeps the newest ``keep`` checkpoints, never deleting the
  one being restored from.

Format: one ``.npy`` per leaf + ``manifest.json`` (paths, dtypes, shapes,
digests, opaque user metadata such as data-pipeline step).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_MARKER = "COMMITTED"
_MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        out.append(("__".join(parts) or "leaf", leaf))
    return out, treedef


def _digest(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save_checkpoint(directory: str, step: int, tree: Any,
                    metadata: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": [], "metadata": metadata or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = f"{name}.npy"
        with open(os.path.join(tmp, fname), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "name": name, "file": fname, "dtype": str(arr.dtype),
            "shape": list(arr.shape), "sha256": _digest(arr)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest *committed* checkpoint step, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, d, _MARKER)):
            steps.append(int(d[5:]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any, *,
                       shardings=None, verify: bool = True) -> Any:
    """Restore into the structure of ``like`` (values replaced).

    ``shardings``: optional matching pytree of NamedSharding — arrays are
    placed directly into the active mesh layout (elastic re-mesh).
    """
    path = os.path.join(directory, f"step_{step:010d}")
    if not os.path.exists(os.path.join(path, _MARKER)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    by_name = {l["name"]: l for l in manifest["leaves"]}
    leaves, treedef = _leaf_paths(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _leaf_paths(shardings)[0]]
    out = []
    for i, (name, leaf) in enumerate(leaves):
        entry = by_name[name]
        arr = np.load(os.path.join(path, entry["file"]))
        if verify and _digest(arr) != entry["sha256"]:
            raise IOError(f"checkpoint digest mismatch for {name}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def read_metadata(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:010d}", _MANIFEST)
    with open(path) as f:
        return json.load(f)["metadata"]


class CheckpointManager:
    """Async save + retention + resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any,
                   metadata: Optional[dict] = None):
        """Snapshot on the caller thread (device_get), write in background."""
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def _run():
            try:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(s for s in (
            int(d[5:]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, d, _MARKER))))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore_latest(self, like: Any, *, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        tree = restore_checkpoint(self.directory, step, like,
                                  shardings=shardings)
        return step, tree
