"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.

``jax.sharding.AxisType`` only exists in newer JAX (absent in 0.4.x); when
it is missing we omit ``axis_types`` — the default (auto) behaviour matches
what ``AxisType.Auto`` requests explicitly.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:          # JAX <= 0.4.x
    AxisType = None


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types when the installed JAX has them."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return make_mesh(shape, axes)
