"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) data x model single pod; (2, 16, 16) pod x data x model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
