import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first use.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  1. abstract params / optimizer state / cache via jax.eval_shape — zero
     allocation (ShapeDtypeStruct stand-ins, the shannon/kernels pattern);
  2. jit(step, in_shardings=..., out_shardings=...).lower(...).compile()
     under the production mesh — any sharding mismatch, OOM-at-compile, or
     unsupported collective fails the cell (it is a bug in the framework);
  3. record memory_analysis / cost_analysis / collective schedule and the
     three roofline terms to results/dryrun/<cell>.json.

Serving cells (prefill/decode) run the paper's technique: int8-quantized
weights (w8a16 baseline).  Training cells run bf16 params + fp32 AdamW.

Usage:
  python -m repro.launch.dryrun --mesh both --arch all --shape all
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape decode_32k \
      --mesh single --quant w8a16 --rules baseline
"""
import argparse
import gc
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.core import roofline as RL
from repro.core.qlinear import FP, QuantMode, W8A16, W8A8
from repro.core.quant import quantize_tree
from repro.launch.mesh import make_production_mesh
from repro.models import registry as R
from repro.optim import make_optimizer, cosine_schedule
from repro.runtime import sharding as S
from repro.runtime import steps as ST

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def _batch_shardings(specs: dict, mesh):
    out = {}
    for k, v in specs.items():
        if k == "cache_index" or v.ndim == 0:
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(mesh, S.batch_spec(mesh, v.ndim, v.shape))
    return out


def build_cell(arch: str, shape_name: str, mesh, rules,
               quant: str = "w8a16", optimizer: str = "adamw",
               kv_quant: bool = False, grad_compression=None):
    """Returns (lowered, model_flops, peak_flops) for one cell."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if kv_quant:
        cfg = _dc.replace(cfg, kv_quant=True)
    shape = SHAPES[shape_name]
    ok, why = cfg.supports(shape)
    if not ok:
        return None, why, None
    key = jax.random.PRNGKey(0)

    with S.use_rules(mesh, rules):
        if shape.kind == "train":
            params = _abstract(lambda k: R.init(k, cfg, dtype=jnp.bfloat16),
                               key)
            opt = make_optimizer(optimizer,
                                 lr=cosine_schedule(3e-4, 100, 10000))
            opt_state = _abstract(opt.init, params)
            step_fn = ST.make_train_step(cfg, opt, mode=FP, remat=True,
                                         mesh=mesh,
                                         grad_compression=grad_compression)
            p_sh = S.tree_shardings(params, mesh, rules)
            o_sh = S.tree_shardings(opt_state, mesh, rules)
            b_specs = cfg.input_specs(shape)
            b_sh = _batch_shardings(b_specs, mesh)
            r_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
            jitted = jax.jit(step_fn,
                             in_shardings=(p_sh, o_sh, b_sh, None),
                             out_shardings=(p_sh, o_sh, None))
            with mesh:
                lowered = jitted.lower(params, opt_state, b_specs, r_spec)
            peak = RL.PEAK_FLOPS_BF16
        else:
            mode = {"w8a16": W8A16, "w8a8": W8A8, "fp": FP}[quant]
            def qinit():
                p = R.init(key, cfg, dtype=jnp.bfloat16)
                return quantize_tree(p) if mode.enabled else p
            params = _abstract(qinit)
            p_sh = S.tree_shardings(params, mesh, rules)
            b_specs = cfg.input_specs(shape)
            b_sh = _batch_shardings(b_specs, mesh)
            if shape.kind == "prefill":
                step_fn = ST.make_prefill_step(cfg, mode=mode)
                out_shape = (shape.global_batch, shape.seq_len, cfg.vocab)
                jitted = jax.jit(
                    step_fn, in_shardings=(p_sh, b_sh),
                    out_shardings=NamedSharding(
                        mesh, S.spec_for("logits", 3, mesh, rules,
                                         out_shape)))
                with mesh:
                    lowered = jitted.lower(params, b_specs)
            else:  # decode
                cache = _abstract(lambda: R.init_cache(
                    cfg, shape.global_batch, shape.seq_len))
                c_sh = S.cache_shardings(cache, mesh, rules)
                step_fn = ST.make_decode_step(cfg, mode=mode)
                jitted = jax.jit(step_fn,
                                 in_shardings=(p_sh, b_sh, c_sh),
                                 out_shardings=(None, c_sh),
                                 donate_argnums=(2,))
                with mesh:
                    lowered = jitted.lower(params, b_specs, cache)
            peak = (RL.PEAK_FLOPS_INT8 if mode.w8a8
                    else RL.PEAK_FLOPS_BF16)
        return lowered, cfg.model_flops(shape), peak


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             rules_name: str = "baseline", quant: str = "w8a16",
             optimizer: str = "adamw", out_dir: str = RESULTS_DIR,
             tag: str = "", kv_quant: bool = False,
             grad_compression=None) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rules = S.RULE_SETS[rules_name]
    cell = f"{arch}/{shape_name}/{mesh_name}" + (f"/{tag}" if tag else "")
    t0 = time.time()
    result = {"cell": cell, "arch": arch, "shape": shape_name,
              "mesh": mesh_name, "rules": rules_name, "quant": quant,
              "status": "ok"}
    try:
        lowered, mf_or_why, peak = build_cell(
            arch, shape_name, mesh, rules, quant=quant,
            optimizer=optimizer, kv_quant=kv_quant,
            grad_compression=grad_compression)
        if lowered is None:
            result["status"] = "skipped"
            result["reason"] = mf_or_why
            return result
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        terms = RL.from_compiled(cell, compiled, chips=mesh.devices.size,
                                 model_flops=mf_or_why, peak_flops=peak)
        result.update(terms.to_dict())   # includes by_op + per-collective
        result["top_ops"] = " ".join(
            f"{op}:f={flops:.2e},b={byts:.2e}"
            for op, flops, byts, _ in terms.op_rows(limit=3))
        result["lower_s"] = round(t_lower, 1)
        result["compile_s"] = round(t_compile, 1)
        try:
            result["memory_analysis"] = str(compiled.memory_analysis())
        except Exception:
            pass
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    finally:
        os.makedirs(out_dir, exist_ok=True)
        fname = cell.replace("/", "__") + ".json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1)
        gc.collect()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="baseline")
    ap.add_argument("--quant", default="w8a16",
                    choices=["w8a16", "w8a8", "fp"])
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "int8"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                cell = f"{arch}/{shape}/{mesh_name}" + \
                    (f"/{args.tag}" if args.tag else "")
                fpath = os.path.join(args.out_dir,
                                     cell.replace("/", "__") + ".json")
                if args.skip_existing and os.path.exists(fpath):
                    with open(fpath) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached ] {cell}: {prev['status']}")
                        continue
                res = run_cell(arch, shape, mesh_name,
                               rules_name=args.rules, quant=args.quant,
                               optimizer=args.optimizer,
                               out_dir=args.out_dir, tag=args.tag,
                               kv_quant=args.kv_quant,
                               grad_compression=args.grad_compression)
                if res["status"] == "ok":
                    print(f"[ok     ] {cell}: compute={res['compute_s']:.4e}s "
                          f"memory={res['memory_s']:.4e}s "
                          f"coll={res['collective_s']:.4e}s "
                          f"bound={res['bound']} "
                          f"(lower {res['lower_s']}s compile "
                          f"{res['compile_s']}s)")
                    if res.get("top_ops"):
                        print(f"          {res['top_ops']}")
                elif res["status"] == "skipped":
                    print(f"[skipped] {cell}: {res['reason']}")
                else:
                    failures += 1
                    print(f"[ERROR  ] {cell}: {res['error']}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
