"""Training launcher: end-to-end loop with checkpointing, resume, watchdog.

Runs on whatever devices exist (CPU for local runs; the production mesh
geometry comes from launch/mesh.py on a real pod).  Demonstrates the full
fault-tolerance story:

  python -m repro.launch.train --arch starcoder2-3b --reduced \
      --steps 200 --ckpt-dir /tmp/ckpt --resume auto

Kill it at any step; rerunning resumes from the newest committed checkpoint
with the data pipeline advanced to the right step (deterministic stream).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models import registry as R
from repro.optim import cosine_schedule, make_optimizer
from repro.runtime import sharding as S
from repro.runtime import steps as ST
from repro.runtime.watchdog import StepTimer, StepWatchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("custom", args.seq_len, args.batch, "train")

    mesh = make_host_mesh()
    rules = S.BASELINE_RULES
    key = jax.random.PRNGKey(args.seed)

    opt = make_optimizer(args.optimizer,
                         lr=cosine_schedule(args.lr, 20, args.steps))
    with S.use_rules(mesh, rules):
        params = R.init(key, cfg)
        opt_state = opt.init(params)
    train_step = ST.make_train_step(
        cfg, opt, mesh=mesh,
        grad_compression=None if args.grad_compression == "none" else
        args.grad_compression)
    p_sh = S.tree_shardings(params, mesh, rules)
    o_sh = S.tree_shardings(opt_state, mesh, rules)
    jitted = jax.jit(train_step, in_shardings=(p_sh, o_sh, None, None),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=(0, 1))

    data = SyntheticLMData(cfg.vocab, shape.seq_len, shape.global_batch,
                           seed=args.seed)
    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume == "auto":
        restored = ckpt.restore_latest({"params": params, "opt": opt_state})
        if restored[0] is not None:
            start_step = restored[0]
            params = restored[1]["params"]
            opt_state = restored[1]["opt"]
            print(f"[resume] restored step {start_step} from "
                  f"{args.ckpt_dir}")

    watchdog = StepWatchdog()
    losses = []
    with S.use_rules(mesh, rules), mesh:
        for step in range(start_step, args.steps):
            tokens, labels = data.batch_at(step)
            batch = {"tokens": jnp.asarray(tokens),
                     "labels": jnp.asarray(labels)}
            rng = jax.random.fold_in(key, step)
            with StepTimer() as t:
                params, opt_state, metrics = jitted(params, opt_state,
                                                    batch, rng)
                loss = float(metrics["loss"])
            warn = watchdog.record(t.elapsed)
            if warn:
                print(f"[watchdog] step {step}: {warn}")
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{t.elapsed*1e3:.0f} ms")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1,
                                {"params": params, "opt": opt_state},
                                metadata={"data_step": step + 1})
    if ckpt:
        ckpt.wait()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[done] loss {first:.3f} -> {last:.3f} over "
          f"{len(losses)} steps; straggler warnings: {watchdog.slow_steps}")
    return 0 if (last < first or start_step > 0) else 1


if __name__ == "__main__":
    sys.exit(main())
