"""Serving launcher: the continuous-batching engine, end to end.

The paper's serving story made live: load (or init) a model, post-training
int8 quantization, measure the prefill service-time curve (including
--max-batch, so batch selection interpolates instead of extrapolating),
pick the largest batch meeting the p99 deadline (Table 4 policy), then
size a slot pool at that batch and drive `repro.engine.Engine` against a
pseudo-Poisson request stream under the wall clock: requests are admitted
into free KV-cache slots as they arrive (shared AdmissionPolicy), every
tick advances ALL active slots with one fused slot-masked decode step of
static shape (the deterministic-execution discipline that makes the p99
predictable), and finished slots are reused immediately — no drain
barrier between request generations.  Reports achieved p99, decoded
tokens/s, and slot occupancy.

  python -m repro.launch.serve --arch starcoder2-3b --reduced \
      --deadline-ms 50 --rate 200

EVERY registry family serves through the engine — dense, moe, ssm and
hybrid share the one fused slot step (per-row cache indices), and the
encoder-conditioned families (encdec/vlm) ride the same step behind a
per-slot prime dispatch that writes each request's cross-attention K/V
into its slot row at admission (see docs/serving.md; time-to-first-token
includes the prime cost).  ``--prefill-chunk`` turns on chunked prefill
(admission-to-first-token drops from prompt_len ticks to
``ceil(prompt_len/chunk)``), ``--temperature`` turns on per-row
``fold_in(rng, position)`` sampling, and ``--spec-k`` turns on
draft-and-verify speculative decoding (``--draft-layers n`` drafts with
the target's own first n layers, no second checkpoint; ``--draft ARCH``
uses a separate small model) — committed outputs stay bit-for-bit the
non-speculative stream.  ``--sim`` runs the virtual-time
BatchQueue simulator backend instead (same admission policy, no model
execution) — the Table 4 sanity check.

Overload robustness (docs/serving.md, "Overload & failure semantics"):
``--interactive-frac``/``--batch-quota`` split the trace into SLO
classes under per-class slot quotas, ``--arrival mmpp`` makes arrivals
bursty, ``--preemption`` lets admission evict lower-class slots and
resume them bit-for-bit exactly, and ``--fault-seed`` injects a
deterministic fault plan (dispatch failures, non-finite logits, torn
block-table rows) to exercise the recovery machinery; the report then
adds per-class p99/ttft, goodput-under-SLO, and fault counters.

Multi-model multiplexing (docs/serving.md, "Multi-model multiplexing"):
``--models a,b`` serves several registry archs as lanes of ONE engine —
each lane keeps its own compiled steps, KV cache, and (paged) block
pool, while ``num_slots`` is a single lease budget the lanes share
tick by tick; ``--model-quota TAG=N`` caps one lane's concurrent slots
through the same (model, class) quota keys ``--batch-quota`` uses.
The report adds per-model p99/ttft/goodput/occupancy lines.

  python -m repro.launch.serve --models starcoder2-3b,qwen2-moe-a2.7b \
      --reduced --model-quota starcoder2-3b=4 --rate 200

Scaling out (docs/serving.md, "Scaling out"): ``--tp N`` serves the
slot pool through the tensor-parallel sharded executor — the same
fused steps under ``shard_map`` on an N-way mesh axis, sharded along
the SLOT axis so outputs stay bit-for-bit the single-device engine
(force a CPU mesh offline with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) — and
``--replicas N`` puts N identically-configured engines behind the
:class:`repro.engine.ReplicaRouter` front-end, which places each
request on the lowest-projected-occupancy replica that its own
admission policy would admit.

  python -m repro.launch.serve --arch starcoder2-3b --reduced \
      --replicas 2 --tp 2 --rate 400

The fused multi-token decode
loop is still timed separately (``--decode-tokens``): it remains the
right tool for fixed-length batch completion, while the engine serves
the ragged live stream.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import batching as bt
from repro.core.qlinear import FP, W8A16, W8A8
from repro.core.quant import quantize_tree, tree_weight_bytes
from repro.models import registry as R
from repro.runtime import steps as ST


def measure_service_curve(step_fn, params, cfg, batches=(1, 4, 16),
                          seq=32, iters=3, max_batch=None,
                          return_times=False):
    """Measured service time at several batch sizes -> LatencyModel.

    ``max_batch``: when given, it joins the measured set — the model is
    then an interpolation over the whole batch range ``choose_batch``
    searches, never an extrapolation beyond what was measured.
    """
    if max_batch is not None:
        batches = tuple(sorted(set(batches) | {int(max_batch)}))
    times = {}
    for b in batches:
        # materialize zeros from input_specs so encoder-conditioned
        # families get their stub embeds with the one authoritative
        # shape/dtype (configs/base.py), not a re-implementation here
        spec = ShapeSpec("serve_curve", seq, b, "prefill")
        batch = {k: jnp.zeros(v.shape, v.dtype)
                 for k, v in cfg.input_specs(spec).items()}
        warm = step_fn(params, batch)   # one warmup call, not three
        warm = warm[0] if isinstance(warm, tuple) else warm
        warm.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            out = step_fn(params, batch)
            out = out[0] if isinstance(out, tuple) else out
            out.block_until_ready()
        times[b] = (time.perf_counter() - t0) / iters
    bs = sorted(times)
    b1, b2 = bs[0], bs[-1]
    per_item = max((times[b2] - times[b1]) / (b2 - b1), 1e-9)
    fixed = max(times[b1] - b1 * per_item, 1e-9)
    model = bt.LatencyModel("measured", fixed * 2.0, per_item * 1.5,
                            fixed, per_item)
    return (model, times) if return_times else model


def measure_decode_tps(cfg, params, mode, batch, *, s_max=128,
                       num_tokens=16, iters=3, seed=0):
    """Tokens/s of the fused decode loop for ``batch`` useful requests.

    One jit'd ``lax.scan`` over ``num_tokens`` steps with the KV cache
    donated — the serving hot loop as it actually runs, not a per-token
    Python loop.  The loop executes at the *bucketed* shape (requests are
    padded up to the static ladder), but throughput counts only the
    ``batch`` real requests' tokens, so the reported tok/s is what the
    chosen policy batch actually delivers, padding waste included.
    Returns (bucketed_batch, tokens_per_s, seconds_per_loop).
    """
    b = ST.bucket_batch(batch)
    loop = ST.jit_decode_loop(
        ST.make_decode_loop(cfg, mode=mode, num_tokens=num_tokens))
    tokens = jnp.ones((b, 1), jnp.int32)
    idx = jnp.zeros((), jnp.int32)

    cache = R.init_cache(cfg, b, s_max)
    out, cache = loop(params, tokens, cache, idx)   # compile + warm
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        # cache was donated: reuse the returned buffer, rewound to step 0
        out, cache = loop(params, tokens, cache, idx)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / iters
    return b, batch * num_tokens / dt, dt


def _parse_model_quotas(pairs):
    """``--model-quota TAG=N`` occurrences -> ``{tag: n}`` quota keys."""
    quotas = {}
    for p in pairs:
        tag, _, n = p.partition("=")
        if not tag or not n or not n.isdigit() or int(n) < 1:
            raise ValueError(
                f"--model-quota wants TAG=N with N >= 1, got {p!r}")
        quotas[tag] = int(n)
    return quotas


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="single-model serving: one registry arch")
    ap.add_argument("--models", default=None, metavar="A,B",
                    help="multi-model multiplexing: comma-separated "
                         "registry arch names served as lanes of ONE "
                         "engine (each arch name is its lane tag; "
                         "mutually exclusive with --arch).  Every lane "
                         "gets its own --n-requests at --rate; the "
                         "service curve / Table 4 batch choice is "
                         "measured on the FIRST lane")
    ap.add_argument("--model-quota", action="append", default=[],
                    metavar="TAG=N",
                    help="engine: cap one lane at N concurrently leased "
                         "slots (repeatable; composes with "
                         "--batch-quota through the same (model, class) "
                         "quota keys)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="w8a16",
                    choices=["fp", "w8a16", "w8a8"])
    ap.add_argument("--deadline-ms", type=float, default=50.0)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="requests/s for the simulated stream")
    ap.add_argument("--n-requests", type=int, default=200)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=16,
                    help="steps of the fused decode loop to time "
                         "(0 disables the decode measurement)")
    ap.add_argument("--prompt-len", type=int, default=4,
                    help="engine: synthetic prompt tokens per request")
    ap.add_argument("--gen-tokens", type=int, default=8,
                    help="engine: tokens to generate per request")
    ap.add_argument("--sim", action="store_true",
                    help="run the virtual-time BatchQueue simulator "
                         "backend instead of the live engine")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="engine: chunked-prefill bucket cap (0 = "
                         "per-token prefill)")
    ap.add_argument("--block-size", type=int, default=0,
                    help="engine: paged KV cache block size in positions "
                         "(power of two; 0 = contiguous slot rows)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="engine: physical KV blocks incl. the reserved "
                         "trash block (0 = every slot can hold a full "
                         "row privately)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="engine: identical leading prompt tokens across "
                         "requests (paged mode shares their KV blocks)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="engine: per-row sampling temperature "
                         "(0 = greedy)")
    ap.add_argument("--interactive-frac", type=float, default=1.0,
                    help="engine: fraction of requests in the "
                         "interactive SLO class (rid-hash split; the "
                         "rest are batch class; 1.0 = single-class, "
                         "today's trace byte-identically)")
    ap.add_argument("--batch-quota", type=int, default=0,
                    help="engine: max slots the batch class may hold "
                         "concurrently (0 = no per-class quota)")
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "mmpp", "diurnal"],
                    help="engine: arrival process (mmpp = bursty "
                         "2-state Markov-modulated Poisson, diurnal = "
                         "sinusoid-modulated day/night curve, both from "
                         "benchmarks/traces.py; needs the repo root on "
                         "PYTHONPATH)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="engine: speculative decoding proposal depth "
                         "(0 = off); needs --draft or --draft-layers")
    ap.add_argument("--draft", default=None,
                    help="engine: draft arch name (e.g. starcoder2-3b) "
                         "for cross-model speculative decoding; "
                         "inherits --reduced, init'd from --seed+2")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="engine: truncated-layer self-draft depth (uses "
                         "the target's own first n layers, no second "
                         "checkpoint; 0 = off)")
    ap.add_argument("--preemption", action="store_true",
                    help="engine: evict strictly-lower-class slots "
                         "under admission pressure and resume them "
                         "with bit-for-bit exact outputs")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="engine: seed a deterministic FaultPlan "
                         "(dispatch failures, non-finite logits, torn "
                         "block-table rows) to exercise recovery")
    ap.add_argument("--n-faults", type=int, default=8,
                    help="engine: faults in the seeded plan")
    ap.add_argument("--replicas", type=int, default=1,
                    help="fleet: serve through a ReplicaRouter over N "
                         "identically-configured engine replicas (each "
                         "with its own slot pool and device state; "
                         "1 = single engine, today's path byte-"
                         "identically)")
    ap.add_argument("--tp", type=int, default=1,
                    help="engine: tensor-parallel width — run the fused "
                         "steps under shard_map on a tp-way mesh axis, "
                         "sharded along the slot axis (bit-identical to "
                         "tp=1; offline, force devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if (args.models is None) == (args.arch is None):
        print("[serve] need exactly one of --arch or --models")
        return 1
    tags = ([t.strip() for t in args.models.split(",") if t.strip()]
            if args.models else [args.arch])
    if len(set(tags)) != len(tags):
        print(f"[serve] --models tags must be unique: {args.models}")
        return 1
    try:
        model_quotas = _parse_model_quotas(args.model_quota)
    except ValueError as e:
        print(f"[serve] {e}")
        return 1
    if unknown := set(model_quotas) - set(tags):
        print(f"[serve] --model-quota names unknown lanes: "
              f"{sorted(unknown)} (lanes: {tags})")
        return 1
    mode = {"fp": FP, "w8a16": W8A16, "w8a8": W8A8}[args.quant]
    lanes = {}
    for i, tag in enumerate(tags):
        lcfg = get_config(tag)
        if args.reduced:
            lcfg = lcfg.reduced()
        lparams = R.init(jax.random.PRNGKey(args.seed + i), lcfg)
        if mode.enabled:
            fp_bytes = tree_weight_bytes(lparams)
            lparams = quantize_tree(lparams, min_size=2048)
            print(f"[quant] {tag} weights {fp_bytes / 1e6:.1f} MB -> "
                  f"{tree_weight_bytes(lparams) / 1e6:.1f} MB "
                  f"({args.quant})")
        lanes[tag] = (lcfg, lparams)
    # the Table 4 curve / batch choice is measured on the first lane
    cfg, params = lanes[tags[0]]

    prefill = jax.jit(ST.make_prefill_step(cfg, mode=mode))
    model, curve = measure_service_curve(prefill, params, cfg,
                                         seq=args.seq,
                                         max_batch=args.max_batch,
                                         return_times=True)
    deadline = args.deadline_ms * 1e-3
    # the chosen batch stays inside the measured range: max_batch is in
    # the measured set, so the Table 4 policy never extrapolates.
    batch = min(bt.choose_batch(model, deadline, args.max_batch),
                max(curve))
    if batch == 0:
        print(f"[serve] deadline {args.deadline_ms} ms unattainable "
              f"(p99(1) = {model.p99_latency(1) * 1e3:.1f} ms)")
        return 1
    print(f"[serve] service(1)={model.service_time(1)*1e3:.2f} ms  "
          f"chosen batch={batch}  modeled p99={model.p99_latency(batch)*1e3:.2f} ms"
          f"  modeled IPS={model.ips(batch):,.0f}")

    if args.decode_tokens > 0:
        bb, tps, dt = measure_decode_tps(
            cfg, params, mode, batch, s_max=max(args.seq * 2, 64),
            num_tokens=args.decode_tokens, seed=args.seed)
        print(f"[decode] fused loop batch={batch} (shape bucket {bb}) "
              f"{args.decode_tokens} steps in {dt*1e3:.1f} ms -> "
              f"{tps:,.0f} tok/s")

    if args.sim:
        reqs = bt.poisson_arrivals(args.rate, args.n_requests, deadline,
                                   args.seed)
        q = bt.BatchQueue(model.service_time, max_batch=batch)
        recs = q.run(reqs)
        lat = []
        arrival = {r.rid: r.arrival_s for r in reqs}
        for rec in recs:
            for rid in rec.rids:
                lat.append(rec.finish_s - arrival[rid])
        met = np.mean([rec.deadlines_met for rec in recs])
        print(f"[sim] {len(recs)} batches, mean size "
              f"{np.mean([len(r.rids) for r in recs]):.1f}; "
              f"p99 latency {bt.p99(lat)*1e3:.2f} ms "
              f"(deadline {args.deadline_ms} ms); "
              f"batches meeting deadline: {met:.1%}; "
              f"throughput {len(lat)/max(r.finish_s for r in recs):,.0f} "
              f"req/s")
        return 0

    # ---- the live continuous-batching engine -------------------------
    from repro import engine as E
    num_slots = ST.bucket_batch(max(batch, 1))
    quotas = dict(model_quotas)
    if args.batch_quota:
        quotas["batch"] = args.batch_quota
    policy = bt.AdmissionPolicy(model.service_time, max_batch=num_slots,
                                class_quotas=quotas or None)
    draft = None
    if args.draft:
        # cross-model draft: its own (small) checkpoint, same vocab —
        # quantized like the target so both serve in the same mode
        dcfg = get_config(args.draft)
        if args.reduced:
            dcfg = dcfg.reduced()
        dparams = R.init(jax.random.PRNGKey(args.seed + 2), dcfg)
        if mode.enabled:
            dparams = quantize_tree(dparams, min_size=2048)
        draft = (dcfg, dparams)
    if args.replicas < 1 or args.tp < 1:
        print(f"[serve] --replicas and --tp must be >= 1 "
              f"(got {args.replicas}, {args.tp})")
        return 1
    backend = None
    if args.tp > 1:
        if not ST.supports_sharded_serving():
            print("[serve] --tp needs jax.experimental.shard_map "
                  "(this jax has none); serve with --tp 1")
            return 1
        try:
            backend = E.ShardedExecutor(tp=args.tp)
        except (RuntimeError, ValueError) as e:
            print(f"[serve] --tp rejected: {e}")
            return 1
        print(f"[serve] sharded executor: tp={args.tp} across "
              f"{len(jax.devices())} visible device(s), slot-axis "
              f"sharding (bit-identical to tp=1)")
    eng_kw = dict(mode=mode, num_slots=num_slots,
                  max_seq=args.prompt_len + args.gen_tokens,
                  policy=policy,
                  prefill_chunk=args.prefill_chunk or None,
                  block_size=args.block_size or None,
                  num_blocks=args.num_blocks or None,
                  temperature=args.temperature,
                  rng=(jax.random.PRNGKey(args.seed + 1)
                       if args.temperature > 0 else None),
                  spec_k=args.spec_k, draft=draft,
                  draft_layers=args.draft_layers or None,
                  backend=backend)

    def build_engine(name=None):
        kw = dict(eng_kw, name=name)
        return (E.Engine(models=lanes, **kw) if args.models
                else E.Engine(cfg, params, **kw))

    try:
        eng = build_engine("replica0" if args.replicas > 1 else None)
    except ValueError as e:
        print(f"[engine] config rejected: {e}")
        return 1
    max_seq = eng.max_seq
    arrival_process = None
    if args.arrival != "poisson":
        try:
            from benchmarks import traces as TR
        except ImportError:
            print(f"[engine] --arrival {args.arrival} needs "
                  "benchmarks/traces.py on PYTHONPATH (run from the "
                  "repo root with PYTHONPATH=src:.)")
            return 1
        arrival_process = (TR.mmpp_process() if args.arrival == "mmpp"
                           else TR.diurnal_process())
    frac = args.interactive_frac
    if not 0.0 <= frac <= 1.0:
        print(f"[engine] --interactive-frac must be in [0, 1]: {frac}")
        return 1
    # rid-hash class split, stable under any n (same rule as
    # benchmarks/traces.py::two_class_trace)
    priority = ("interactive" if frac >= 1.0 else
                (lambda rid: "interactive"
                 if (rid * 2654435761) % 1000 < frac * 1000 else "batch"))
    # one sub-trace per lane (each lane draws prompts in its OWN vocab
    # and carries its lane tag; rids offset per lane so the merged
    # trace keys uniquely), merged by arrival — the single-model path
    # is the one-lane case of the same loop, byte-identical to before
    reqs = []
    for i, tag in enumerate(tags):
        lcfg, _ = lanes[tag]
        sub = E.synthetic_requests(
            args.n_requests, rate_per_s=args.rate, vocab=lcfg.vocab,
            prompt_len=args.prompt_len, max_new_tokens=args.gen_tokens,
            deadline_s=deadline, seed=args.seed + i,
            shared_prefix_len=args.shared_prefix_len,
            source_shape=R.source_shape(lcfg),
            priority=priority, arrival_process=arrival_process,
            model=tag if args.models else None)
        reqs.extend(dataclasses.replace(r, rid=r.rid + i * args.n_requests)
                    for r in sub)
    reqs.sort(key=lambda r: r.arrival_s)
    plan = (E.FaultPlan.random(args.fault_seed, n_faults=args.n_faults,
                               num_slots=num_slots)
            if args.fault_seed is not None else None)
    if args.replicas > 1:
        # ---- the replica fleet behind the router front-end ----------
        if plan is not None:
            print("[serve] --fault-seed wants a single engine "
                  "(--replicas 1): a shared FaultPlan would replay the "
                  "same fired list on every replica")
            return 1
        try:
            fleet = [eng] + [build_engine(f"replica{i}")
                             for i in range(1, args.replicas)]
        except ValueError as e:
            print(f"[engine] config rejected: {e}")
            return 1
        router = E.ReplicaRouter(fleet)
        for member in fleet:     # compile BEFORE the wall clock starts
            member.warmup()
        rrep = router.serve(reqs, clock="wall",
                            preemption=args.preemption)
        print(f"[router] {args.replicas} replicas x {num_slots} slots "
              f"x {max_seq} positions (tp={args.tp}); "
              f"{len(rrep.results)} requests, {rrep.refused} refused")
        occ = "  ".join(f"{n}={rrep.replica_occupancy[n]:.1%}"
                        f"({rrep.replica_requests[n]} reqs)"
                        for n in rrep.replica_names)
        print(f"[router] fleet p99 {rrep.p99_latency_s*1e3:.2f} ms "
              f"(deadline {args.deadline_ms} ms); "
              f"{rrep.tokens_per_s:,.0f} tok/s decoded, goodput "
              f"{rrep.goodput_tokens_per_s:,.0f} tok/s; "
              f"ttft {rrep.mean_ttft_s*1e3:.2f} ms mean")
        print(f"[router] per-replica occupancy: {occ}")
        if rrep.leaked_blocks:
            print(f"[router] WARNING: {rrep.leaked_blocks} KV blocks "
                  f"leaked across the fleet")
        return 0
    eng.warmup()         # compile before the clock starts: the measured
    try:                                      # p99 is serving, not tracing
        rep = eng.serve(reqs, clock="wall", preemption=args.preemption,
                        fault_plan=plan)
    except E.RequestTooLong as e:
        print(f"[engine] request rejected at admission: {e}")
        return 1
    deadline_of = {r.rid: r.deadline_s for r in reqs}
    met = np.mean([r.finish_s <= deadline_of[r.rid]
                   for r in rep.results]) if rep.results else 0.0
    print(f"[engine] {rep.num_slots} slots x {max_seq} positions; "
          f"{len(rep.results)} requests in {rep.ticks} ticks "
          f"({rep.wall_s:.2f} s wall)")
    print(f"[engine] achieved p99 {rep.p99_latency_s*1e3:.2f} ms "
          f"(deadline {args.deadline_ms} ms, met {met:.1%}); "
          f"{rep.tokens_per_s:,.0f} tok/s decoded; "
          f"slot occupancy {rep.mean_occupancy:.1%} mean / "
          f"{max(rep.occupancy) if rep.occupancy else 0} peak; "
          f"{rep.admissions_while_busy} admissions while mid-generation "
          f"(no drain barrier)")
    print(f"[engine] time-to-first-token {rep.mean_ttft_s*1e3:.2f} ms mean "
          f"/ {rep.p99_ttft_s*1e3:.2f} ms p99 "
          f"(prefill chunk {rep.prefill_chunk or 'off'})")
    if rep.spec_k:
        print(f"[engine] speculative: k={rep.spec_k} "
              f"({eng.dcfg.name} draft), "
              f"{rep.accepted_per_dispatch:.2f} tokens committed per "
              f"dispatch, {rep.latency_per_token_s*1e3:.2f} ms/token "
              f"mean (outputs bit-for-bit the non-speculative stream)")
    if rep.block_size:
        print(f"[engine] paged KV: {rep.num_blocks} blocks x "
              f"{rep.block_size} positions, {rep.kv_hbm_bytes/1e6:.2f} MB "
              f"resident; peak {rep.peak_blocks_used} blocks used "
              f"({rep.mean_block_util:.1%} mean util); "
              f"{rep.shared_block_hits} shared-prefix block hits "
              f"({rep.shared_hit_rate:.1%} of demand, "
              f"{rep.prefill_tokens_skipped} prefill tokens skipped); "
              f"effective concurrency {rep.effective_concurrency:.1f}")
    if len(rep.class_p99_latency_s) > 1:
        print(f"[engine] goodput {rep.goodput_tokens_per_s:,.0f} tok/s "
              f"({rep.slo_attainment:.1%} of requests made their "
              f"deadline)")
        for cls in bt.PRIORITY_CLASSES:
            if cls not in rep.class_p99_latency_s:
                continue
            print(f"[engine]   {cls:11s} "
                  f"p99 {rep.class_p99_latency_s[cls]*1e3:8.2f} ms, "
                  f"ttft {rep.class_mean_ttft_s[cls]*1e3:.2f} ms mean / "
                  f"{rep.class_p99_ttft_s[cls]*1e3:.2f} ms p99")
    if rep.model_p99_latency_s:
        for tag in tags:
            if tag not in rep.model_p99_latency_s:
                continue
            print(f"[engine]   model {tag}: "
                  f"p99 {rep.model_p99_latency_s[tag]*1e3:8.2f} ms, "
                  f"ttft {rep.model_mean_ttft_s[tag]*1e3:.2f} ms mean / "
                  f"{rep.model_p99_ttft_s[tag]*1e3:.2f} ms p99, "
                  f"goodput {rep.model_goodput_tokens_per_s[tag]:,.0f} "
                  f"tok/s, occupancy "
                  f"{rep.model_mean_occupancy[tag]:.1%} of the shared "
                  f"lease"
                  + (f" (quota {quotas[tag]})" if tag in quotas else ""))
    if rep.preempted or rep.dropped or rep.failed or rep.unfinished:
        print(f"[engine] retirement: {rep.preempted} preemptions "
              f"(exact resume), {rep.dropped} dropped, {rep.failed} "
              f"failed, {rep.unfinished} unfinished")
    if plan is not None:
        print(f"[engine] faults: {len(plan.fired)} fired "
              f"({rep.dispatch_retries} dispatch retries, "
              f"{rep.nonfinite_samples} non-finite samples caught, "
              f"{rep.torn_rows_repaired} torn rows repaired, "
              f"{rep.leaked_blocks} leaked blocks, "
              f"{rep.stuck_ticks} stuck ticks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
